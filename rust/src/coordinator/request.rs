//! Request/response types.

use std::time::Instant;

/// Monotonically assigned request identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// Generation parameters.
#[derive(Debug, Clone, Copy)]
pub struct GenParams {
    pub max_new_tokens: usize,
    /// Greedy if false; seeded multinomial-ish (argmax over perturbed
    /// logits) if true.
    pub sample: bool,
    pub seed: u64,
}

impl Default for GenParams {
    fn default() -> Self {
        Self { max_new_tokens: 8, sample: false, seed: 0 }
    }
}

/// An inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub params: GenParams,
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, params: GenParams) -> Self {
        Self { id: RequestId(id), prompt, params, arrived: Instant::now() }
    }
}

/// Pick the next token from a logits row: greedy argmax, or (when
/// `params.sample`) argmax over Gumbel-perturbed logits seeded by the
/// request seed and the decode step.  The perturbation stream depends on
/// nothing else, so batched, unbatched and preempted-then-resumed
/// execution of the same request produce the **identical** token stream —
/// the property the engine's correctness tests pin down.
pub fn sample_token(logits: &[f32], params: &GenParams, step: usize) -> i32 {
    let mut rng = crate::util::Rng::with_seed(
        params.seed ^ (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        let v = if params.sample {
            // seeded Gumbel-max: argmax(v + G) samples softmax(v)
            v - (-rng.f64().max(1e-12).ln()).ln() as f32
        } else {
            v
        };
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    /// Queue time (arrival → prefill start).
    pub queue_s: f64,
    /// Total latency (arrival → last token).
    pub total_s: f64,
    /// Time to first token.
    pub ttft_s: f64,
}
