//! Dynamic batcher: greedily groups queued requests into the batch sizes
//! the AOT artifacts support, bounded by a wait deadline.
//!
//! Policy (Triton/vLLM-style admission): release a group as soon as the
//! largest supported batch fills; otherwise release whatever is queued
//! once the *oldest* request has waited `max_wait`.  FIFO order is
//! preserved — a group is always a prefix of the queue.

use super::request::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Supported group sizes, ascending (from the artifact manifest).
    pub batch_sizes: Vec<usize>,
    /// Deadline: oldest queued request may wait at most this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { batch_sizes: vec![1, 2, 4, 8], max_wait: Duration::from_millis(20) }
    }
}

/// FIFO queue + grouping policy.  Single-threaded by design — the server
/// wraps it in its own loop.
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<Request>,
    admitted: u64,
    released: u64,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(!cfg.batch_sizes.is_empty(), "need at least one batch size");
        let mut cfg = cfg;
        cfg.batch_sizes.sort_unstable();
        Self { cfg, queue: VecDeque::new(), admitted: 0, released: 0 }
    }

    pub fn push(&mut self, r: Request) {
        self.admitted += 1;
        self.queue.push_back(r);
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Conservation counters: (admitted, released).
    pub fn counts(&self) -> (u64, u64) {
        (self.admitted, self.released)
    }

    pub fn max_batch(&self) -> usize {
        *self.cfg.batch_sizes.last().unwrap()
    }

    /// Largest supported batch size ≤ n (None if n below the smallest).
    fn fit(&self, n: usize) -> Option<usize> {
        self.cfg.batch_sizes.iter().rev().find(|&&b| b <= n).copied()
    }

    /// Try to form a group at time `now`.  Returns a queue *prefix*.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<Request>> {
        let n = self.queue.len();
        if n == 0 {
            return None;
        }
        let full = n >= self.max_batch();
        let expired = now.duration_since(self.queue[0].arrived) >= self.cfg.max_wait;
        if !(full || expired) {
            return None;
        }
        let take = self.fit(n).unwrap_or_else(|| self.cfg.batch_sizes[0].min(n));
        // (when n < smallest supported size we still take everything the
        //  smallest executable can hold: smaller groups pad — but with
        //  batch_sizes starting at 1 this branch never under-fills)
        let take = take.min(n);
        let group: Vec<Request> = self.queue.drain(..take).collect();
        self.released += group.len() as u64;
        Some(group)
    }

    /// Drain the whole queue immediately, ignoring the deadline and the
    /// supported group sizes.  The continuous-batching engine calls this
    /// when it is otherwise idle: an empty engine should never sit out a
    /// batching deadline, because iteration-level scheduling can admit the
    /// stragglers one by one as later arrivals trickle in.
    pub fn flush(&mut self) -> Vec<Request> {
        let group: Vec<Request> = self.queue.drain(..).collect();
        self.released += group.len() as u64;
        group
    }

    /// Time until the oldest request's deadline (for sleep scheduling).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|r| {
            self.cfg.max_wait.saturating_sub(now.duration_since(r.arrived))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenParams;
    use crate::util::proptest::forall;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], GenParams::default())
    }

    fn mk(batch_sizes: Vec<usize>, wait_ms: u64) -> Batcher {
        Batcher::new(BatcherConfig { batch_sizes, max_wait: Duration::from_millis(wait_ms) })
    }

    #[test]
    fn releases_full_batch_immediately() {
        let mut b = mk(vec![1, 2, 4], 1000);
        for i in 0..5 {
            b.push(req(i));
        }
        let g = b.poll(Instant::now()).expect("full group");
        assert_eq!(g.len(), 4);
        assert_eq!(g[0].id.0, 0, "FIFO prefix");
        assert_eq!(b.queued(), 1);
        // remaining single request only flushes at deadline
        assert!(b.poll(Instant::now()).is_none());
    }

    #[test]
    fn deadline_flushes_partial() {
        let mut b = mk(vec![1, 2, 4], 10);
        b.push(req(0));
        b.push(req(1));
        b.push(req(2));
        assert!(b.poll(Instant::now()).is_none(), "no flush before deadline");
        let later = Instant::now() + Duration::from_millis(11);
        let g = b.poll(later).expect("deadline flush");
        assert_eq!(g.len(), 2, "largest supported size ≤ 3");
        assert_eq!(b.queued(), 1);
        let g2 = b.poll(later + Duration::from_millis(11)).expect("second flush");
        assert_eq!(g2.len(), 1);
    }

    #[test]
    fn flush_drains_everything_and_keeps_counts() {
        let mut b = mk(vec![4], 1000);
        for i in 0..3 {
            b.push(req(i));
        }
        assert!(b.poll(Instant::now()).is_none(), "below group size, before deadline");
        let g = b.flush();
        assert_eq!(g.len(), 3);
        assert_eq!(b.queued(), 0);
        assert_eq!(b.counts(), (3, 3));
        assert!(b.flush().is_empty());
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = mk(vec![1], 50);
        assert!(b.next_deadline(Instant::now()).is_none());
        b.push(req(0));
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(50));
    }

    #[test]
    fn prop_conservation_and_fifo() {
        forall(64, |rng| {
            let sizes = match rng.u32(0, 3) {
                0 => vec![1],
                1 => vec![1, 2, 4],
                _ => vec![1, 2, 4, 8],
            };
            let mut b = mk(sizes.clone(), 5);
            let total = rng.usize(1, 40);
            let mut next_id = 0u64;
            let mut out = Vec::new();
            let mut now = Instant::now();
            let mut to_add = total;
            while out.len() < total {
                // interleave arrivals and polls
                let add = rng.usize(0, 4).min(to_add);
                for _ in 0..add {
                    b.push(req(next_id));
                    next_id += 1;
                }
                to_add -= add;
                now += Duration::from_millis(rng.u64() % 8);
                if let Some(g) = b.poll(now) {
                    assert!(!g.is_empty());
                    assert!(g.len() <= *sizes.last().unwrap(), "never exceeds max batch");
                    out.extend(g.iter().map(|r| r.id.0));
                }
            }
            // every admitted request released exactly once, in FIFO order
            let (adm, rel) = b.counts();
            assert_eq!(adm, total as u64);
            assert_eq!(rel, total as u64);
            assert_eq!(out, (0..total as u64).collect::<Vec<_>>(), "FIFO violated");
            assert_eq!(b.queued(), 0);
        });
    }

    #[test]
    fn prop_group_sizes_supported() {
        forall(48, |rng| {
            let mut b = mk(vec![1, 2, 4, 8], 0); // zero wait → flush whenever polled
            let n = rng.usize(1, 30);
            for i in 0..n {
                b.push(req(i as u64));
            }
            let mut now = Instant::now();
            while b.queued() > 0 {
                now += Duration::from_millis(1);
                if let Some(g) = b.poll(now) {
                    assert!(
                        [1usize, 2, 4, 8].contains(&g.len()),
                        "group size {} unsupported",
                        g.len()
                    );
                }
            }
        });
    }
}
