//! Serving metrics: counters + latency percentiles, including the
//! per-token latencies (TTFT, inter-token) the streaming delivery path
//! records, resident-vs-swapped KV footprint gauges, prefix-cache
//! hit/eviction gauges, and the cross-replica migration /
//! cross-precision requantization counters.  Replica metrics merge into
//! one cluster view via [`Metrics::merge`].  Percentiles are ceil-based
//! nearest-rank over a sort-once [`LatencySnapshot`].

use std::time::Instant;

/// Latency sample store with percentile queries (exact — fine for the
/// demo scale; a production build would use t-digest).  For several
/// queries over the same state, take a [`LatencyStats::snapshot`] and
/// query that: it sorts **once**, where the convenience
/// [`LatencyStats::percentile`] sorts per call.
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples: Vec<f64>,
}

impl LatencyStats {
    pub fn record(&mut self, seconds: f64) {
        self.samples.push(seconds);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sort the samples once into a queryable [`LatencySnapshot`].
    pub fn snapshot(&self) -> LatencySnapshot {
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencySnapshot { sorted }
    }

    /// Exact percentile (ceil-based nearest-rank); `p` in [0, 100].
    /// One-off convenience — sorts per call; use [`LatencyStats::snapshot`]
    /// when querying several percentiles of the same state.
    pub fn percentile(&self, p: f64) -> f64 {
        self.snapshot().percentile(p)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }

    /// Fold another store's samples into this one (cluster aggregation).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples.extend_from_slice(&other.samples);
    }
}

/// Sorted-once view of a [`LatencyStats`]: percentile queries are an
/// index, not a sort.
#[derive(Debug, Clone)]
pub struct LatencySnapshot {
    sorted: Vec<f64>,
}

impl LatencySnapshot {
    /// **Ceil-based nearest-rank** percentile: the smallest sample with
    /// at least `p`% of the set at or below it — rank `⌈p/100 · n⌉`
    /// (1-indexed), clamped to `[1, n]`.  The previous round-based rank
    /// (`round(p/100 · (n−1))`) underreported tails on small samples:
    /// p99 of 50 samples picked the 49th sample (the true p98) instead
    /// of the 50th.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.sorted.len();
        if n == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        self.sorted[rank.clamp(1, n) - 1]
    }

    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(0.0)
    }

    pub fn count(&self) -> usize {
        self.sorted.len()
    }
}

/// Aggregated serving metrics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests_in: u64,
    pub requests_done: u64,
    pub tokens_generated: u64,
    pub groups_executed: u64,
    pub batch_occupancy_sum: u64,
    /// Sequences swapped out by the engine when the KV pool ran dry.
    pub preemptions: u64,
    /// Preempted sequences swapped back in (resumed decoding).
    pub resumes: u64,
    /// KV tokens of resident (decoding) sequences — gauge, refreshed per
    /// step.
    pub kv_resident_tokens: u64,
    /// KV tokens retained host-side by swapped-out sequences — gauge,
    /// refreshed per step.  Swapped KV still costs memory; this is what
    /// lets capacity planning distinguish it from resident KV.
    pub kv_swapped_tokens: u64,
    /// High-water mark of `kv_swapped_tokens`.
    pub kv_swapped_peak: u64,
    /// Prefix-cache hits (live shares + free-list restores) — cumulative
    /// gauge mirrored from [`KvSharing`](super::kv::KvSharing) per step.
    pub prefix_hits: u64,
    /// Logical blocks admitted (the hit-rate denominator) — gauge.
    pub prefix_logical: u64,
    /// Prefix-cache registrations the eviction policy invalidated — gauge.
    pub prefix_evictions: u64,
    /// Swapped sequences moved to a peer replica by the cluster's
    /// rebalancer (counted on the cluster clock, not per replica).
    pub migrations: u64,
    /// Migrations that crossed a precision boundary — the carried KV was
    /// dropped and the target re-prefills (counted on the cluster clock).
    pub requants: u64,
    /// Migrations that were disaggregated prefill→decode handoffs: a
    /// prefill-role replica finished a prefill and the sequence moved to
    /// a decode replica (counted on the cluster clock; subset of
    /// `migrations`).
    pub prefill_handoffs: u64,
    /// KV rebuilds performed by THIS replica for cross-precision
    /// arrivals: one prefill over prompt + generated tokens each.
    pub reprefills: u64,
    /// Tokens drafted at the speculative low-bit plane-prefix width.
    pub spec_drafted: u64,
    /// Drafted tokens the wide-precision verify pass accepted.
    pub spec_accepted: u64,
    /// Accepted-draft-length distribution: `spec_accept_hist[a]` counts
    /// the speculating sequence-steps that accepted exactly `a` drafted
    /// tokens (and so emitted `a + 1`).  Indexed 0..=spec_k; grown on
    /// demand so replicas at different `spec_k` merge cleanly.
    pub spec_accept_hist: Vec<u64>,
    /// Tokens emitted per speculating sequence-step (`accepted + 1`
    /// samples, one per sequence per decode step with a non-empty draft)
    /// — mean > 1 is the whole point of drafting.
    pub spec_tokens_per_step: LatencyStats,
    pub queue: LatencyStats,
    pub ttft: LatencyStats,
    /// Inter-token latency: gap between consecutive streamed tokens of
    /// one request (spans swap-out time — preemption is visible here).
    pub itl: LatencyStats,
    pub total: LatencyStats,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Metrics {
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn finish(&mut self) {
        self.finished = Some(Instant::now());
    }

    pub fn wall_seconds(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(s), Some(f)) => f.duration_since(s).as_secs_f64(),
            (Some(s), None) => s.elapsed().as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Generated tokens per wall-clock second.
    pub fn throughput_tok_s(&self) -> f64 {
        let w = self.wall_seconds();
        if w > 0.0 {
            self.tokens_generated as f64 / w
        } else {
            0.0
        }
    }

    /// Fraction of admitted KV blocks served by the prefix cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_logical == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / self.prefix_logical as f64
    }

    /// Mean batch occupancy across executed groups.
    pub fn mean_occupancy(&self) -> f64 {
        if self.groups_executed == 0 {
            return 0.0;
        }
        self.batch_occupancy_sum as f64 / self.groups_executed as f64
    }

    /// One speculating sequence-step: `drafted` tokens were drafted,
    /// `accepted` of them survived the wide-precision verify.
    pub fn record_spec_step(&mut self, drafted: u64, accepted: u64) {
        debug_assert!(accepted <= drafted);
        self.spec_drafted += drafted;
        self.spec_accepted += accepted;
        let slot = accepted as usize;
        if self.spec_accept_hist.len() <= slot {
            self.spec_accept_hist.resize(slot + 1, 0);
        }
        self.spec_accept_hist[slot] += 1;
        self.spec_tokens_per_step.record((accepted + 1) as f64);
    }

    /// Fraction of drafted tokens the verify pass accepted (0 when
    /// nothing was drafted).
    pub fn spec_accept_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_drafted as f64
    }

    /// Fold a replica's metrics into this aggregate: counters and the
    /// simultaneous KV gauges sum, latency samples concatenate, and
    /// **this** metrics' wall clock is kept (the cluster brackets the
    /// run; per-replica clocks measure the same wall time).  Peaks take
    /// the max: per-replica high-water marks happen at different steps,
    /// so summing them would claim a simultaneous footprint that never
    /// existed (the max is a conservative lower bound on the true
    /// cluster-wide peak).
    pub fn merge(&mut self, other: &Metrics) {
        self.requests_in += other.requests_in;
        self.requests_done += other.requests_done;
        self.tokens_generated += other.tokens_generated;
        self.groups_executed += other.groups_executed;
        self.batch_occupancy_sum += other.batch_occupancy_sum;
        self.preemptions += other.preemptions;
        self.resumes += other.resumes;
        self.kv_resident_tokens += other.kv_resident_tokens;
        self.kv_swapped_tokens += other.kv_swapped_tokens;
        self.kv_swapped_peak = self.kv_swapped_peak.max(other.kv_swapped_peak);
        self.prefix_hits += other.prefix_hits;
        self.prefix_logical += other.prefix_logical;
        self.prefix_evictions += other.prefix_evictions;
        self.migrations += other.migrations;
        self.requants += other.requants;
        self.prefill_handoffs += other.prefill_handoffs;
        self.reprefills += other.reprefills;
        self.spec_drafted += other.spec_drafted;
        self.spec_accepted += other.spec_accepted;
        if self.spec_accept_hist.len() < other.spec_accept_hist.len() {
            self.spec_accept_hist.resize(other.spec_accept_hist.len(), 0);
        }
        for (slot, &n) in other.spec_accept_hist.iter().enumerate() {
            self.spec_accept_hist[slot] += n;
        }
        self.spec_tokens_per_step.merge(&other.spec_tokens_per_step);
        self.queue.merge(&other.queue);
        self.ttft.merge(&other.ttft);
        self.itl.merge(&other.itl);
        self.total.merge(&other.total);
    }

    pub fn report(&self) -> String {
        // one sort per stat for the whole report (p50/p95/max each)
        let queue = self.queue.snapshot();
        let ttft = self.ttft.snapshot();
        let itl = self.itl.snapshot();
        let total = self.total.snapshot();
        // speculative line only when something was drafted — plain-decode
        // reports keep their exact shape
        let spec = if self.spec_drafted > 0 {
            format!(
                "\nspeculative: {}/{} drafts accepted ({:.0}%) | {:.2} tok/step | accept-len {:?}",
                self.spec_accepted,
                self.spec_drafted,
                100.0 * self.spec_accept_rate(),
                self.spec_tokens_per_step.mean(),
                self.spec_accept_hist,
            )
        } else {
            String::new()
        };
        format!(
            "requests: {}/{} done | tokens: {} | wall: {:.2}s | {:.1} tok/s | occupancy {:.2} | \
             preempted {} (resumed {}, migrated {}, requantized {}, prefill handoffs {})\n\
             kv tokens resident/swapped: {}/{} (peak swapped {})\n\
             prefix cache: {}/{} blocks hit ({:.0}%), {} evicted\n\
             queue  p50/p95/max: {:.1}/{:.1}/{:.1} ms\n\
             ttft   p50/p95/max: {:.1}/{:.1}/{:.1} ms\n\
             itl    p50/p95/max: {:.1}/{:.1}/{:.1} ms\n\
             total  p50/p95/max: {:.1}/{:.1}/{:.1} ms{spec}",
            self.requests_done,
            self.requests_in,
            self.tokens_generated,
            self.wall_seconds(),
            self.throughput_tok_s(),
            self.mean_occupancy(),
            self.preemptions,
            self.resumes,
            self.migrations,
            self.requants,
            self.prefill_handoffs,
            self.kv_resident_tokens,
            self.kv_swapped_tokens,
            self.kv_swapped_peak,
            self.prefix_hits,
            self.prefix_logical,
            100.0 * self.prefix_hit_rate(),
            self.prefix_evictions,
            queue.percentile(50.0) * 1e3,
            queue.percentile(95.0) * 1e3,
            queue.max() * 1e3,
            ttft.percentile(50.0) * 1e3,
            ttft.percentile(95.0) * 1e3,
            ttft.max() * 1e3,
            itl.percentile(50.0) * 1e3,
            itl.percentile(95.0) * 1e3,
            itl.max() * 1e3,
            total.percentile(50.0) * 1e3,
            total.percentile(95.0) * 1e3,
            total.max() * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact() {
        let mut s = LatencyStats::default();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0] {
            s.record(v);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 10.0);
        assert_eq!(s.percentile(50.0), 5.0); // ceil nearest-rank: ⌈0.5·10⌉ = 5th
        assert_eq!(s.max(), 10.0);
        assert!((s.mean() - 5.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_is_ceil_nearest_rank_on_the_tail() {
        // the regression fixture: 50 samples 1..=50, recorded shuffled so
        // the snapshot really sorts.  The old round-based rank
        // (round(p/100·49)) underreported tails — p95 picked the 48th
        // sample (the true p96 boundary sat at 47.5 and rounded down in
        // half-even engines); ceil-based nearest-rank is the textbook
        // definition: smallest sample with ≥ p% at or below it.
        let mut s = LatencyStats::default();
        for i in 0..50u64 {
            s.record(((i * 37) % 50 + 1) as f64); // 1..=50, permuted
        }
        let snap = s.snapshot();
        assert_eq!(snap.count(), 50);
        assert_eq!(snap.percentile(99.0), 50.0, "p99 of 50 = ⌈49.5⌉ = 50th sample");
        assert_eq!(snap.percentile(95.0), 48.0, "p95 of 50 = ⌈47.5⌉ = 48th sample");
        assert_eq!(snap.percentile(50.0), 25.0);
        assert_eq!(snap.percentile(2.0), 1.0);
        assert_eq!(snap.percentile(0.0), 1.0, "p0 clamps to the minimum");
        assert_eq!(snap.percentile(100.0), 50.0);
        assert_eq!(snap.max(), 50.0);
        // the one-off convenience agrees with the snapshot
        assert_eq!(s.percentile(99.0), snap.percentile(99.0));
        // empty stays zero
        assert_eq!(LatencyStats::default().snapshot().percentile(50.0), 0.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::default();
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn occupancy_and_throughput() {
        let mut m = Metrics::default();
        m.start();
        m.groups_executed = 4;
        m.batch_occupancy_sum = 10;
        assert!((m.mean_occupancy() - 2.5).abs() < 1e-12);
        m.tokens_generated = 100;
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.finish();
        assert!(m.throughput_tok_s() > 0.0);
        assert!(m.report().contains("occupancy 2.50"));
    }

    #[test]
    fn spec_steps_accumulate_and_merge_across_replicas() {
        // one replica speculating at spec_k=4, one at spec_k=2: the
        // histograms have different lengths and must merge elementwise
        let mut a = Metrics::default();
        a.record_spec_step(4, 4); // fully accepted: 5 tokens this step
        a.record_spec_step(4, 0); // nothing stuck: plain-decode step
        assert_eq!(a.spec_drafted, 8);
        assert_eq!(a.spec_accepted, 4);
        assert!((a.spec_accept_rate() - 0.5).abs() < 1e-12);
        assert_eq!(a.spec_accept_hist, vec![1, 0, 0, 0, 1]);
        assert!((a.spec_tokens_per_step.mean() - 3.0).abs() < 1e-12, "(5 + 1) / 2");

        let mut b = Metrics::default();
        b.record_spec_step(2, 1);
        assert_eq!(b.spec_accept_hist, vec![0, 1]);
        a.merge(&b);
        assert_eq!(a.spec_drafted, 10);
        assert_eq!(a.spec_accepted, 5);
        assert_eq!(a.spec_accept_hist, vec![1, 1, 0, 0, 1], "shorter hist merges in place");
        assert_eq!(a.spec_tokens_per_step.count(), 3);
        assert!(a.report().contains("speculative:"), "drafting shows in the report");
        // the short side grows to the long side too
        let mut c = Metrics::default();
        c.record_spec_step(2, 2);
        c.merge(&a);
        assert_eq!(c.spec_accept_hist, vec![1, 1, 1, 0, 1]);
        // a replica that never drafted reports no speculative line
        assert!(!Metrics::default().report().contains("speculative:"));
    }

    #[test]
    fn merge_sums_counters_and_concats_samples() {
        let mut a = Metrics::default();
        a.start();
        a.tokens_generated = 10;
        a.requests_done = 2;
        a.ttft.record(0.5);
        a.itl.record(0.1);
        let b = Metrics {
            tokens_generated: 5,
            requests_done: 1,
            kv_swapped_peak: 7,
            prefix_hits: 6,
            prefix_logical: 8,
            prefix_evictions: 2,
            migrations: 3,
            requants: 2,
            prefill_handoffs: 2,
            reprefills: 1,
            ..Metrics::default()
        };
        std::thread::sleep(std::time::Duration::from_millis(2));
        a.finish();
        let wall = a.wall_seconds();
        a.merge(&b);
        a.ttft.record(1.5);
        assert_eq!(a.tokens_generated, 15);
        assert_eq!(a.requests_done, 3);
        assert_eq!(a.ttft.count(), 2);
        assert_eq!(a.itl.count(), 1);
        assert_eq!(a.kv_swapped_peak, 7);
        assert_eq!(a.prefix_hits, 6);
        assert_eq!(a.prefix_logical, 8);
        assert_eq!(a.prefix_evictions, 2);
        assert_eq!(a.migrations, 3);
        assert_eq!(a.requants, 2);
        assert_eq!(a.prefill_handoffs, 2);
        assert_eq!(a.reprefills, 1);
        assert!((a.prefix_hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(a.wall_seconds(), wall, "merge keeps the aggregate's clock");
    }
}
