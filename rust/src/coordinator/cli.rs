//! `apllm serve` — the end-to-end serving demo: continuous-batching
//! scheduler under a synthetic Poisson workload, over either the real
//! PJRT model artifacts (`pjrt` feature) or the pack-once AP-GEMM sim
//! backend (always available; `--sim` forces it).

use super::backend::{Backend, SimBackend};
#[cfg(feature = "pjrt")]
use super::backend::PjrtBackend;
use super::request::{GenParams, Request};
use super::scheduler::{Scheduler, SchedulerConfig};
#[cfg(feature = "pjrt")]
use crate::runtime::{artifacts_dir, Engine, ModelRunner};
use crate::anyhow::Result;
use crate::util::Rng;
use std::time::{Duration, Instant};

pub struct ServeArgs {
    pub requests: usize,
    pub rate_per_s: f64,
    pub max_new: usize,
    pub prompt_len: usize,
    pub seed: u64,
    /// Use the pack-once sim backend even when `pjrt` is compiled in.
    pub sim: bool,
}

impl Default for ServeArgs {
    fn default() -> Self {
        Self { requests: 16, rate_per_s: 8.0, max_new: 8, prompt_len: 12, seed: 0, sim: false }
    }
}

pub fn parse_args(args: &[String]) -> ServeArgs {
    let mut a = ServeArgs::default();
    let mut it = args.iter();
    while let Some(flag) = it.next() {
        let mut val = |name: &str| -> String {
            it.next().unwrap_or_else(|| panic!("{name} needs a value")).clone()
        };
        match flag.as_str() {
            "--requests" => a.requests = val("--requests").parse().expect("usize"),
            "--rate" => a.rate_per_s = val("--rate").parse().expect("f64"),
            "--max-new" => a.max_new = val("--max-new").parse().expect("usize"),
            "--prompt-len" => a.prompt_len = val("--prompt-len").parse().expect("usize"),
            "--seed" => a.seed = val("--seed").parse().expect("u64"),
            "--sim" => a.sim = true,
            other => panic!("unknown flag {other}"),
        }
    }
    a
}

/// Drive one backend through the Poisson workload; returns (report,
/// scheduler) so callers can append backend-specific stats.
fn drive<B: Backend>(backend: B, a: &ServeArgs) -> Result<(String, Scheduler<B>)> {
    let vocab = backend.vocab() as u32;
    let mut sched = Scheduler::new(
        backend,
        SchedulerConfig { kv_blocks: 128, block_tokens: 16, max_running: 8 },
    );

    // Poisson arrivals, fixed prompt length, deterministic content
    let mut rng = Rng::with_seed(a.seed);
    let mut arrivals: Vec<(f64, Request)> = Vec::new();
    let mut t = 0.0;
    for i in 0..a.requests {
        t += rng.exponential(a.rate_per_s);
        let prompt: Vec<i32> = (0..a.prompt_len).map(|_| rng.u32(1, vocab) as i32).collect();
        arrivals.push((
            t,
            Request::new(
                i as u64,
                prompt,
                GenParams { max_new_tokens: a.max_new, sample: false, seed: i as u64 },
            ),
        ));
    }

    sched.metrics.start();
    let start = Instant::now();
    let mut next = 0;
    let mut responses = Vec::new();
    while next < arrivals.len() || !sched.is_idle() {
        let now = start.elapsed().as_secs_f64();
        while next < arrivals.len() && arrivals[next].0 <= now {
            let (_, mut req) = arrivals[next].clone();
            req.arrived = Instant::now();
            sched.submit(req);
            next += 1;
        }
        if sched.is_idle() {
            if next < arrivals.len() {
                let wait = arrivals[next].0 - now;
                std::thread::sleep(Duration::from_secs_f64(wait.max(0.0).min(0.05)));
            }
            continue;
        }
        responses.extend(sched.step()?);
    }
    sched.metrics.finish();

    let mut report = String::new();
    report.push_str(&format!(
        "serving demo: {} requests, Poisson rate {}/s, prompt {} tokens, {} new tokens each\n",
        a.requests, a.rate_per_s, a.prompt_len, a.max_new
    ));
    report.push_str(&sched.metrics.report());
    report.push('\n');
    let sample: Vec<i32> = responses
        .iter()
        .find(|r| r.id.0 == 0)
        .map(|r| r.tokens.clone())
        .unwrap_or_default();
    report.push_str(&format!("request 0 generated: {sample:?}\n"));
    Ok((report, sched))
}

/// Run the demo over the REAL PJRT artifacts; returns the metrics report.
/// Used by the CLI and the llm_serving example.
#[cfg(feature = "pjrt")]
pub fn run_serving_demo(a: &ServeArgs) -> Result<String> {
    let dir = artifacts_dir();
    eprintln!("loading artifacts from {} ...", dir.display());
    let engine = Engine::load(&dir)?;
    let runner = ModelRunner::new(&engine)?;
    let t0 = Instant::now();
    let n = engine.warmup(&["prefill", "decode"])?;
    eprintln!("compiled {n} model executables in {:.2?}", t0.elapsed());

    let backend = PjrtBackend::new(&runner)?;
    let (report, _sched) = drive(backend, a)?;
    Ok(report)
}

/// Run the demo over the pack-once AP-GEMM sim backend: weights are
/// decomposed+packed once at startup, every decode step packs only its
/// activation batch through the recycling arena — the §3.3 flow end to
/// end, with the stats to prove it appended to the report.
pub fn run_sim_serving_demo(a: &ServeArgs) -> Result<String> {
    let (vocab, max_seq, dim) = (256usize, 256usize, 128usize);
    let backend =
        SimBackend::with_ap_gemm(vocab, max_seq, vec![1, 2, 4, 8], dim, 2, 2, a.seed ^ 0xAB);
    let packed_bytes = backend.packed_weight_bytes();
    let (mut report, sched) = drive(backend, a)?;
    let s = sched.backend().ap_stats().expect("ap backend");
    report.push_str(&format!(
        "pack-once: weight packs {}, packed weight bytes {}, activation packs {}, \
         arena allocs {}, arena reuses {}\n",
        s.weight_packs, packed_bytes, s.act_packs, s.arena_allocs, s.arena_reuses
    ));
    Ok(report)
}

/// Pick the demo the build supports: real PJRT artifacts when the `pjrt`
/// feature is compiled in (unless `--sim`), the pack-once sim backend
/// otherwise.  Shared by `apllm serve` and the llm_serving example.
pub fn run_demo(a: &ServeArgs) -> Result<String> {
    #[cfg(feature = "pjrt")]
    let result = if a.sim { run_sim_serving_demo(a) } else { run_serving_demo(a) };
    #[cfg(not(feature = "pjrt"))]
    let result = {
        if !a.sim {
            eprintln!("(pjrt feature not compiled in — serving over the pack-once sim backend)");
        }
        run_sim_serving_demo(a)
    };
    result
}

pub fn cmd_serve(args: &[String]) {
    let a = parse_args(args);
    match run_demo(&a) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            std::process::exit(1);
        }
    }
}
