//! `apllm serve` — the end-to-end serving demo: PJRT model artifacts +
//! continuous-batching scheduler under a synthetic Poisson workload.

use super::backend::PjrtBackend;
use super::request::{GenParams, Request};
use super::scheduler::{Scheduler, SchedulerConfig};
use crate::runtime::{artifacts_dir, Engine, ModelRunner};
use crate::util::Rng;
use std::time::{Duration, Instant};

pub struct ServeArgs {
    pub requests: usize,
    pub rate_per_s: f64,
    pub max_new: usize,
    pub prompt_len: usize,
    pub seed: u64,
}

impl Default for ServeArgs {
    fn default() -> Self {
        Self { requests: 16, rate_per_s: 8.0, max_new: 8, prompt_len: 12, seed: 0 }
    }
}

pub fn parse_args(args: &[String]) -> ServeArgs {
    let mut a = ServeArgs::default();
    let mut it = args.iter();
    while let Some(flag) = it.next() {
        let mut val = |name: &str| -> String {
            it.next().unwrap_or_else(|| panic!("{name} needs a value")).clone()
        };
        match flag.as_str() {
            "--requests" => a.requests = val("--requests").parse().expect("usize"),
            "--rate" => a.rate_per_s = val("--rate").parse().expect("f64"),
            "--max-new" => a.max_new = val("--max-new").parse().expect("usize"),
            "--prompt-len" => a.prompt_len = val("--prompt-len").parse().expect("usize"),
            "--seed" => a.seed = val("--seed").parse().expect("u64"),
            other => panic!("unknown flag {other}"),
        }
    }
    a
}

/// Run the demo; returns (responses, metrics report).  Used by both the
/// CLI and the llm_serving example.
pub fn run_serving_demo(a: &ServeArgs) -> anyhow::Result<String> {
    let dir = artifacts_dir();
    eprintln!("loading artifacts from {} ...", dir.display());
    let engine = Engine::load(&dir)?;
    let runner = ModelRunner::new(&engine)?;
    let t0 = Instant::now();
    let n = engine.warmup(&["prefill", "decode"])?;
    eprintln!("compiled {n} model executables in {:.2?}", t0.elapsed());

    let backend = PjrtBackend::new(&runner)?;
    let vocab = runner.cfg.vocab as i32;
    let mut sched = Scheduler::new(
        backend,
        SchedulerConfig { kv_blocks: 128, block_tokens: 16, max_running: 8 },
    );

    // Poisson arrivals, fixed prompt length, deterministic content
    let mut rng = Rng::with_seed(a.seed);
    let mut arrivals: Vec<(f64, Request)> = Vec::new();
    let mut t = 0.0;
    for i in 0..a.requests {
        t += rng.exponential(a.rate_per_s);
        let prompt: Vec<i32> = (0..a.prompt_len).map(|_| rng.u32(1, vocab as u32) as i32).collect();
        arrivals.push((
            t,
            Request::new(
                i as u64,
                prompt,
                GenParams { max_new_tokens: a.max_new, sample: false, seed: i as u64 },
            ),
        ));
    }

    sched.metrics.start();
    let start = Instant::now();
    let mut next = 0;
    let mut responses = Vec::new();
    while next < arrivals.len() || !sched.is_idle() {
        let now = start.elapsed().as_secs_f64();
        while next < arrivals.len() && arrivals[next].0 <= now {
            let (_, mut req) = arrivals[next].clone();
            req.arrived = Instant::now();
            sched.submit(req);
            next += 1;
        }
        if sched.is_idle() {
            if next < arrivals.len() {
                let wait = arrivals[next].0 - now;
                std::thread::sleep(Duration::from_secs_f64(wait.max(0.0).min(0.05)));
            }
            continue;
        }
        responses.extend(sched.step()?);
    }
    sched.metrics.finish();

    let mut report = String::new();
    report.push_str(&format!(
        "serving demo: {} requests, Poisson rate {}/s, prompt {} tokens, {} new tokens each\n",
        a.requests, a.rate_per_s, a.prompt_len, a.max_new
    ));
    report.push_str(&sched.metrics.report());
    report.push('\n');
    let sample: Vec<i32> = responses
        .iter()
        .find(|r| r.id.0 == 0)
        .map(|r| r.tokens.clone())
        .unwrap_or_default();
    report.push_str(&format!("request 0 generated: {sample:?}\n"));
    Ok(report)
}

pub fn cmd_serve(args: &[String]) {
    let a = parse_args(args);
    match run_serving_demo(&a) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            std::process::exit(1);
        }
    }
}
