//! `apllm serve` — the end-to-end serving demo: a synthetic Poisson
//! workload over either the real PJRT model artifacts (`pjrt` feature) or
//! the pack-once AP-GEMM sim backend (always available; `--sim` forces
//! it).  Both paths serve through the ONE **continuous-batching engine**;
//! `--admission optimistic|reserve` selects the KV booking policy
//! (`reserve` = the retired group scheduler's full-budget, never-preempt
//! semantics; `--group-scheduler` survives as a deprecated alias).
//! `--replicas N` (≥2) serves a **multi-replica cluster** behind the
//! router (`--route-policy round-robin|least-loaded`), with
//! `--roles p,d,m` assigning prefill/decode/mixed roles round-robin for
//! a disaggregated deployment.  `--spec-k N` turns on self-speculative
//! decoding (draft from the `--draft-bits`-wide plane prefix of the same
//! pack, verify at serving width); streams stay byte-identical to plain
//! decode.

#[cfg(feature = "pjrt")]
use super::backend::PjrtBackend;
use super::backend::SimBackend;
use super::cluster::{Cluster, ClusterSpec, ReplicaSpec};
use super::engine::{AdmissionPolicy, Engine, EngineConfig};
use super::request::{responses_of, Response};
use super::router::{ReplicaRole, RoutePolicy};
use super::server::{replay_trace, Stepper};
use super::trace::{generate, ArrivalKind, TimedRequest, TraceConfig};
use crate::anyhow::{bail, Context, Result};
use crate::model::PrecisionConfig;
#[cfg(feature = "pjrt")]
use crate::runtime::{artifacts_dir, Engine as PjrtEngine, ModelRunner};
use std::time::Duration;
#[cfg(feature = "pjrt")]
use std::time::Instant;

pub struct ServeArgs {
    pub requests: usize,
    pub rate_per_s: f64,
    pub max_new: usize,
    pub prompt_len: usize,
    pub seed: u64,
    /// Use the pack-once sim backend even when `pjrt` is compiled in.
    pub sim: bool,
    /// KV admission policy: `Optimistic` (default) overcommits and
    /// preempts under pressure; `Reserve` books each request's full
    /// `prompt + max_new` budget up front and never preempts (the
    /// retired group scheduler's semantics).
    pub admission: AdmissionPolicy,
    /// Engine replicas behind the router (≥2 = cluster demo).
    pub replicas: usize,
    /// How the router picks a replica.
    pub route_policy: RoutePolicy,
    /// Replica roles assigned round-robin across `replicas` (`p`refill /
    /// `d`ecode / `m`ixed); empty = every replica Mixed (the symmetric
    /// baseline).  Requires a cluster (`--replicas ≥ 2`) and at least one
    /// prefill-capable assignment.
    pub roles: Vec<ReplicaRole>,
    /// Host-wide GEMM worker budget (`0` = the `APLLM_THREADS` /
    /// available-parallelism default): a lone engine gets it all, a
    /// cluster splits it across replicas ([`ClusterSpec::worker_budget`]).
    pub workers: usize,
    /// Speculative decoding: tokens drafted ahead per sequence per step
    /// from the low-bit plane prefix of the serving pack (`0` = off).
    pub spec_k: usize,
    /// Draft width in bit-planes (must stay strictly below the serving
    /// width; the cluster demo clamps it per replica's precision).
    pub draft_bits: u32,
}

impl Default for ServeArgs {
    fn default() -> Self {
        Self {
            requests: 16,
            rate_per_s: 8.0,
            max_new: 8,
            prompt_len: 12,
            seed: 0,
            sim: false,
            admission: AdmissionPolicy::Optimistic,
            replicas: 1,
            route_policy: RoutePolicy::LeastLoaded,
            roles: Vec::new(),
            workers: 0,
            spec_k: 0,
            draft_bits: 1,
        }
    }
}

/// The flag list every parse error repeats — a bad flag must produce a
/// recoverable error naming the alternatives, never kill the process.
const VALID_FLAGS: &str = "--requests N, --rate R, --max-new N, --prompt-len N, --seed N, \
     --replicas N, --route-policy round-robin|least-loaded, --roles p,d,m, --workers N, \
     --spec-k N, --draft-bits N, --sim, --admission optimistic|reserve, \
     --group-scheduler (deprecated alias for --admission reserve)";

fn take_value<'a>(it: &mut std::slice::Iter<'a, String>, name: &str) -> Result<&'a str> {
    it.next()
        .map(String::as_str)
        .with_context(|| format!("{name} needs a value (valid flags: {VALID_FLAGS})"))
}

fn parse_value<T>(it: &mut std::slice::Iter<'_, String>, name: &str, kind: &str) -> Result<T>
where
    T: std::str::FromStr,
    T::Err: std::fmt::Display,
{
    let raw = take_value(it, name)?;
    raw.parse().with_context(|| format!("{name} expects {kind}, got {raw:?}"))
}

pub fn parse_args(args: &[String]) -> Result<ServeArgs> {
    let mut a = ServeArgs::default();
    let mut it = args.iter();
    while let Some(flag) = it.next() {
        match flag.as_str() {
            "--requests" => a.requests = parse_value(&mut it, "--requests", "a count")?,
            "--rate" => a.rate_per_s = parse_value(&mut it, "--rate", "a rate (req/s)")?,
            "--max-new" => a.max_new = parse_value(&mut it, "--max-new", "a token count")?,
            "--prompt-len" => a.prompt_len = parse_value(&mut it, "--prompt-len", "a length")?,
            "--seed" => a.seed = parse_value(&mut it, "--seed", "an integer seed")?,
            "--replicas" => {
                a.replicas = parse_value(&mut it, "--replicas", "a replica count")?;
                if a.replicas == 0 {
                    bail!("--replicas must be ≥ 1");
                }
            }
            "--route-policy" => {
                let raw = take_value(&mut it, "--route-policy")?;
                a.route_policy = RoutePolicy::parse(raw).with_context(|| {
                    format!("--route-policy expects round-robin|least-loaded, got {raw:?}")
                })?;
            }
            "--roles" => {
                let raw = take_value(&mut it, "--roles")?;
                a.roles = raw
                    .split(',')
                    .map(|s| {
                        ReplicaRole::parse(s).with_context(|| {
                            format!(
                                "--roles expects a comma list of p[refill]|d[ecode]|m[ixed], \
                                 got {s:?} in {raw:?}"
                            )
                        })
                    })
                    .collect::<Result<_>>()?;
                if a.roles.is_empty() {
                    bail!("--roles needs at least one role (p|d|m)");
                }
            }
            "--workers" => a.workers = parse_value(&mut it, "--workers", "a worker count")?,
            "--spec-k" => a.spec_k = parse_value(&mut it, "--spec-k", "a draft length")?,
            "--draft-bits" => {
                a.draft_bits = parse_value(&mut it, "--draft-bits", "a plane count")?;
            }
            "--sim" => a.sim = true,
            "--admission" => {
                let raw = take_value(&mut it, "--admission")?;
                a.admission = match raw {
                    "optimistic" => AdmissionPolicy::Optimistic,
                    "reserve" => AdmissionPolicy::Reserve,
                    other => {
                        bail!("--admission expects optimistic|reserve, got {other:?}")
                    }
                };
            }
            "--group-scheduler" => {
                eprintln!(
                    "(--group-scheduler is deprecated: the group scheduler was folded into \
                     the engine — use --admission reserve)"
                );
                a.admission = AdmissionPolicy::Reserve;
            }
            other => bail!("unknown flag {other} (valid flags: {VALID_FLAGS})"),
        }
    }
    if a.admission == AdmissionPolicy::Reserve && a.replicas > 1 {
        bail!(
            "--admission reserve serves a single replica in this demo (the cluster drives \
             optimistic continuous-batching engines); drop it or use --replicas 1"
        );
    }
    if a.spec_k > 0 && a.draft_bits == 0 {
        bail!("--spec-k needs --draft-bits ≥ 1 (the draft runs on a non-empty plane prefix)");
    }
    if !a.roles.is_empty() {
        if a.replicas < 2 {
            bail!("--roles splits work across a cluster; use --replicas ≥ 2");
        }
        // roles cycle over the replicas — the ASSIGNED set must contain a
        // prefill-capable replica or every request would be unroutable
        // (Cluster::new would panic on the same condition; fail the parse
        // with a recoverable error instead)
        let assigned_prefill =
            (0..a.replicas).any(|i| a.roles[i % a.roles.len()].accepts_prefill());
        if !assigned_prefill {
            bail!(
                "--roles {:?} with --replicas {} assigns no prefill-capable replica \
                 (add a p or m entry)",
                a.roles.iter().map(|r| r.label()).collect::<Vec<_>>().join(","),
                a.replicas
            );
        }
    }
    if a.spec_k > 0 && a.admission == AdmissionPolicy::Reserve {
        bail!(
            "--spec-k needs --admission optimistic (reserve admission books the full \
             budget up front and never speculates)"
        );
    }
    Ok(a)
}

/// Deterministic Poisson trace for the demo workload.
fn build_trace(a: &ServeArgs, vocab: usize) -> Vec<TimedRequest> {
    generate(&TraceConfig {
        kind: ArrivalKind::Poisson { rate: a.rate_per_s },
        requests: a.requests,
        prompt_len: (a.prompt_len, a.prompt_len + 1),
        max_new: (a.max_new, a.max_new + 1),
        vocab,
        seed: a.seed,
        ..TraceConfig::default()
    })
}

/// Drive one stepper through the Poisson workload; returns (report,
/// responses) so callers can append backend-specific stats.
fn drive<S: Stepper>(s: &mut S, a: &ServeArgs, vocab: usize) -> Result<(String, Vec<Response>)> {
    let trace = build_trace(a, vocab);
    let events = replay_trace(s, &trace)?;
    let responses = responses_of(&events);
    let mut report = String::new();
    report.push_str(&format!(
        "serving demo: {} requests, Poisson rate {}/s, prompt {} tokens, {} new tokens each\n",
        a.requests, a.rate_per_s, a.prompt_len, a.max_new
    ));
    report.push_str(&s.metrics().report());
    report.push('\n');
    let sample: Vec<i32> = responses
        .iter()
        .find(|r| r.id.0 == 0)
        .map(|r| r.tokens.clone())
        .unwrap_or_default();
    report.push_str(&format!("request 0 generated: {sample:?}\n"));
    Ok((report, responses))
}

/// Vocab of the demo sim model (shared by every replica).
const DEMO_VOCAB: usize = 256;

fn ap_sim_backend(seed: u64) -> (SimBackend, usize) {
    let (max_seq, dim) = (256usize, 128usize);
    (
        SimBackend::with_ap_gemm(DEMO_VOCAB, max_seq, vec![1, 2, 4, 8], dim, 2, 2, seed ^ 0xAB),
        DEMO_VOCAB,
    )
}

fn pack_once_stats(backend: &SimBackend, packed_bytes: usize) -> String {
    let s = backend.ap_stats().expect("ap backend");
    format!(
        "pack-once: weight packs {}, packed weight bytes {}, activation packs {}, \
         arena allocs {}, arena reuses {}\n",
        s.weight_packs, packed_bytes, s.act_packs, s.arena_allocs, s.arena_reuses
    )
}

/// The ONE demo pool shape, shared by every serving demo (PJRT, sim
/// engine, legacy-parity reserve, and each cluster replica) so the
/// configurations can't drift apart.
const DEMO_KV_BLOCKS: usize = 128;
const DEMO_BLOCK_TOKENS: usize = 16;
const DEMO_MAX_RUNNING: usize = 8;

fn demo_engine_config() -> EngineConfig {
    EngineConfig {
        kv_blocks: DEMO_KV_BLOCKS,
        block_tokens: DEMO_BLOCK_TOKENS,
        max_running: DEMO_MAX_RUNNING,
        batcher: super::batcher::BatcherConfig {
            batch_sizes: vec![1, 2, 4, 8],
            max_wait: Duration::from_millis(2),
        },
        // everything else (prefix sharing, LRU eviction, optimistic
        // admission, no speculation; Cluster::new flips prefill_hold on
        // for prefill-role replicas) is the engine default
        ..EngineConfig::default()
    }
}

/// Run the demo over the REAL PJRT artifacts; returns the metrics report.
/// Used by the CLI and the llm_serving example.  Serves through the same
/// continuous-batching [`Engine`] as the sim path — ONE serving stack for
/// every backend.  Speculation auto-disarms here: PJRT KV is real device
/// tensors, not position-only state, so the backend declines
/// [`super::backend::Backend::set_draft_bits`] and the engine falls back
/// to plain decode.
#[cfg(feature = "pjrt")]
pub fn run_serving_demo(a: &ServeArgs) -> Result<String> {
    let dir = artifacts_dir();
    eprintln!("loading artifacts from {} ...", dir.display());
    let engine = PjrtEngine::load(&dir)?;
    let runner = ModelRunner::new(&engine)?;
    let t0 = Instant::now();
    let n = engine.warmup(&["prefill", "decode"])?;
    eprintln!("compiled {n} model executables in {:.2?}", t0.elapsed());

    let backend = PjrtBackend::new(&runner)?;
    let vocab = runner.cfg.vocab;
    let mut eng = Engine::new(
        backend,
        EngineConfig {
            workers: a.workers,
            spec_k: a.spec_k,
            draft_bits: a.draft_bits,
            admission: a.admission,
            ..demo_engine_config()
        },
    );
    let (mut report, _) = drive(&mut eng, a, vocab)?;
    let c = eng.counters();
    report.push_str(&format!(
        "engine: steps {}, prefills {}, preemptions {}, resumes {}, rejected {}\n",
        c.steps, c.prefills, c.preemptions, c.resumes, c.rejected
    ));
    Ok(report)
}

/// Legacy-parity demo over the pack-once AP-GEMM sim backend: the SAME
/// continuous-batching engine forced to [`AdmissionPolicy::Reserve`] —
/// the retired group scheduler's full-budget, never-preempt admission —
/// kept as the baseline the optimistic engine demo is compared against.
pub fn run_sim_serving_demo(a: &ServeArgs) -> Result<String> {
    engine_demo(a, AdmissionPolicy::Reserve)
}

/// Continuous-batching engine demo over the pack-once AP-GEMM sim
/// backend: batcher-fed admission under `--admission`, prefix-shared
/// incremental KV with swap preemption, per-step join/leave batching —
/// weights decomposed+packed once at startup, every step packing only
/// its activation batch through the recycling arena, with the counters
/// to prove both appended.
pub fn run_engine_serving_demo(a: &ServeArgs) -> Result<String> {
    engine_demo(a, a.admission)
}

fn engine_demo(a: &ServeArgs, admission: AdmissionPolicy) -> Result<String> {
    let (backend, vocab) = ap_sim_backend(a.seed);
    let packed_bytes = backend.packed_weight_bytes();
    // clamp the draft strictly below the backend's serving width (the
    // cluster demo does the same per replica) — the demo sim backend
    // serves W2, so at most the 1-bit MSB plane drafts
    let max_draft = backend.serving_bits().map_or(0, |(nw, _)| nw.saturating_sub(1));
    let cfg = EngineConfig {
        workers: a.workers,
        spec_k: a.spec_k,
        draft_bits: a.draft_bits.min(max_draft),
        admission,
        ..demo_engine_config()
    };
    let mut eng = Engine::new(backend, cfg);
    let (mut report, _) = drive(&mut eng, a, vocab)?;
    let c = eng.counters();
    report.push_str(&format!(
        "engine: steps {}, prefills {}, preemptions {}, resumes {}, rejected {}\n",
        c.steps, c.prefills, c.preemptions, c.resumes, c.rejected
    ));
    if eng.spec_k() > 0 {
        report.push_str(&format!(
            "speculative: spec_k {}, drafted {}, accepted {}\n",
            eng.spec_k(),
            c.drafted,
            c.accepted
        ));
    }
    let sh = eng.pool().sharing();
    report.push_str(&format!(
        "kv: {}/{} blocks free after drain | fresh {}, shared {}, restored {}, cow {}, peak {}\n",
        eng.pool().free_blocks(),
        eng.pool().total_blocks(),
        sh.fresh_allocs,
        sh.shared_live,
        sh.cache_restores,
        sh.cow_copies,
        sh.peak_used,
    ));
    report.push_str(&pack_once_stats(eng.backend(), packed_bytes));
    Ok(report)
}

/// Multi-replica cluster demo: `a.replicas` pack-once engine replicas at
/// **alternating precisions (W4A4 / W2A2), all slicing one shared 4-bit
/// superset weight store** — the any-precision memory model: the weight
/// is packed once for the whole cluster and each replica serves its own
/// plane prefix.  `--roles` cycles prefill/decode/mixed roles across the
/// replicas for a disaggregated deployment.  Merged metrics plus a
/// per-replica load/KV breakdown; swapped sequences requantize across
/// the precision boundary when no same-precision peer has headroom.
pub fn run_cluster_serving_demo(a: &ServeArgs) -> Result<String> {
    let store = super::backend::superset_store(DEMO_VOCAB, 128, 4, a.seed ^ 0xAB);
    let mut spec = ClusterSpec::new(a.route_policy);
    if a.workers > 0 {
        spec = spec.worker_budget(a.workers);
    }
    for i in 0..a.replicas {
        let p = if i % 2 == 0 { PrecisionConfig::W4A4 } else { PrecisionConfig::W2A2 };
        let role =
            if a.roles.is_empty() { ReplicaRole::Mixed } else { a.roles[i % a.roles.len()] };
        // per-replica spec config: every replica drafts from the plane
        // prefix of ITS OWN serving width, so the draft is clamped below
        // each precision independently (W4 replicas draft up to 3 planes,
        // W2 replicas at most 1)
        let cfg = EngineConfig {
            spec_k: a.spec_k,
            draft_bits: a.draft_bits.min(p.nw.saturating_sub(1)),
            ..demo_engine_config()
        };
        spec = spec.replica(ReplicaSpec::new(format!("r{i}"), p).role(role).engine(cfg));
    }
    let mut cluster = Cluster::new(spec, |r| {
        SimBackend::with_shared_store(
            256,
            vec![1, 2, 4, 8],
            store.clone(),
            r.precision.nw,
            r.precision.nx,
        )
    });
    let (mut report, _) = drive(&mut cluster, a, DEMO_VOCAB)?;
    report.push_str(&format!(
        "cluster: {} replicas, policy {:?}, routed {}, completed {}, unroutable {}, \
         migrated {} (requantized {}, prefill handoffs {})\n",
        cluster.replicas(),
        cluster.router().policy(),
        cluster.router().routed,
        cluster.router().completed,
        cluster.unroutable(),
        cluster.migrations(),
        cluster.requants(),
        cluster.prefill_handoffs(),
    ));
    // one superset pack serves every precision — report its bytes ONCE
    // for the whole cluster, against what per-precision stores would cost
    let served: std::collections::BTreeSet<u32> = cluster
        .engines()
        .iter()
        .filter_map(|e| e.backend().serving_bits())
        .map(|(nw, _)| nw)
        .collect();
    let per_precision: usize = served.iter().map(|&nw| store.packed_bytes_at(nw)).sum();
    report.push_str(&format!(
        "weights: one superset store, {} bytes packed once for {} precisions \
         (per-precision stores would hold {} bytes)\n",
        store.packed_bytes(),
        served.len(),
        per_precision,
    ));
    for (eng, rep) in cluster.engines().iter().zip(cluster.router().replicas()) {
        let c = eng.counters();
        let sh = eng.pool().sharing();
        report.push_str(&format!(
            "  {} ({}, {}): completed {}, steps {}, preempt {}, kv free {}/{}, \
             fresh {}, shared {}, cow {}\n",
            rep.name,
            rep.precision.label(),
            rep.role.label(),
            c.completed,
            c.steps,
            c.preemptions,
            eng.pool().free_blocks(),
            eng.pool().total_blocks(),
            sh.fresh_allocs,
            sh.shared_live,
            sh.cow_copies,
        ));
    }
    cluster.check_invariants().context("cluster invariants after drain")?;
    Ok(report)
}

/// Pick the demo the build supports: real PJRT artifacts when the `pjrt`
/// feature is compiled in (unless `--sim`); otherwise the pack-once sim
/// backend — a router-driven cluster when `--replicas ≥ 2`, else the
/// continuous-batching engine under the `--admission` policy.  Every
/// path is the same engine.  Shared by `apllm serve` and the
/// llm_serving example.
pub fn run_demo(a: &ServeArgs) -> Result<String> {
    if a.workers > 0 {
        // cap the global default pool too (activation packing etc.), not
        // just the per-replica GEMM pools
        crate::util::set_threads(a.workers);
    }
    #[cfg(feature = "pjrt")]
    if !a.sim {
        if a.replicas <= 1 {
            return run_serving_demo(a);
        }
        eprintln!(
            "(cluster serving is sim-only for now — {} replicas run over the pack-once sim \
             backend, NOT the PJRT artifacts)",
            a.replicas
        );
    }
    #[cfg(not(feature = "pjrt"))]
    if !a.sim {
        eprintln!("(pjrt feature not compiled in — serving over the pack-once sim backend)");
    }
    if a.replicas > 1 {
        run_cluster_serving_demo(a)
    } else {
        run_engine_serving_demo(a)
    }
}

pub fn cmd_serve(args: &[String]) {
    let a = match parse_args(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("serve: {e}");
            std::process::exit(2);
        }
    };
    match run_demo(&a) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            std::process::exit(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_args_roundtrip() {
        let a = parse_args(&s(&["--requests", "3", "--rate", "2.5", "--sim"])).unwrap();
        assert_eq!(a.requests, 3);
        assert_eq!(a.rate_per_s, 2.5);
        assert!(a.sim);
        assert_eq!(a.admission, AdmissionPolicy::Optimistic, "optimistic is the default");
        assert_eq!(a.replicas, 1, "single replica is the default");
        let a = parse_args(&s(&["--admission", "reserve"])).unwrap();
        assert_eq!(a.admission, AdmissionPolicy::Reserve);
        let a = parse_args(&s(&["--admission", "optimistic"])).unwrap();
        assert_eq!(a.admission, AdmissionPolicy::Optimistic);
        // the deprecated alias still parses, mapping onto reserve
        let a = parse_args(&s(&["--group-scheduler"])).unwrap();
        assert_eq!(a.admission, AdmissionPolicy::Reserve);
        let a = parse_args(&s(&["--replicas", "3", "--route-policy", "round-robin"])).unwrap();
        assert_eq!(a.replicas, 3);
        assert_eq!(a.route_policy, RoutePolicy::RoundRobin);
        let a = parse_args(&s(&["--route-policy", "least-loaded"])).unwrap();
        assert_eq!(a.route_policy, RoutePolicy::LeastLoaded);
        let a = parse_args(&s(&["--workers", "4"])).unwrap();
        assert_eq!(a.workers, 4);
        assert_eq!(parse_args(&s(&[])).unwrap().workers, 0, "default inherits APLLM_THREADS");
        let a = parse_args(&s(&["--spec-k", "4", "--draft-bits", "2"])).unwrap();
        assert_eq!(a.spec_k, 4);
        assert_eq!(a.draft_bits, 2);
        let d = parse_args(&s(&[])).unwrap();
        assert_eq!(d.spec_k, 0, "speculation is opt-in");
        assert_eq!(d.draft_bits, 1, "default draft width is the MSB plane");
        assert!(d.roles.is_empty(), "default topology is all-mixed");
        let a = parse_args(&s(&["--replicas", "3", "--roles", "p,d,m"])).unwrap();
        assert_eq!(
            a.roles,
            vec![ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Mixed]
        );
        let a = parse_args(&s(&["--replicas", "2", "--roles", "prefill,decode"])).unwrap();
        assert_eq!(a.roles, vec![ReplicaRole::Prefill, ReplicaRole::Decode]);
    }

    #[test]
    fn parse_args_roles_validation() {
        let e = parse_args(&s(&["--replicas", "2", "--roles", "x"])).unwrap_err().to_string();
        assert!(e.contains("p[refill]") && e.contains('x'), "{e}");
        let e = parse_args(&s(&["--roles", "p,d"])).unwrap_err().to_string();
        assert!(e.contains("--replicas ≥ 2"), "roles need a cluster: {e}");
        let e = parse_args(&s(&["--replicas", "3", "--roles", "d"])).unwrap_err().to_string();
        assert!(e.contains("no prefill-capable"), "{e}");
        // a p entry beyond the replica count doesn't help: 2 replicas
        // cycling d,d,p never assign the p
        let e =
            parse_args(&s(&["--replicas", "2", "--roles", "d,d,p"])).unwrap_err().to_string();
        assert!(e.contains("no prefill-capable"), "{e}");
        // …but within reach it does
        assert!(parse_args(&s(&["--replicas", "3", "--roles", "d,d,p"])).is_ok());
    }

    #[test]
    fn parse_args_bad_flag_is_an_error_not_a_panic() {
        let e = parse_args(&s(&["--bogus"])).unwrap_err().to_string();
        assert!(e.contains("--bogus") && e.contains("--requests"), "lists options: {e}");
        let e = parse_args(&s(&["--requests"])).unwrap_err().to_string();
        assert!(e.contains("needs a value") && e.contains("--rate"), "{e}");
        let e = parse_args(&s(&["--requests", "many"])).unwrap_err().to_string();
        assert!(e.contains("expects a count") && e.contains("many"), "{e}");
        let e = parse_args(&s(&["--route-policy", "fastest"])).unwrap_err().to_string();
        assert!(e.contains("round-robin") && e.contains("fastest"), "{e}");
        let e = parse_args(&s(&["--replicas", "0"])).unwrap_err().to_string();
        assert!(e.contains("≥ 1"), "{e}");
        let e = parse_args(&s(&["--admission", "eager"])).unwrap_err().to_string();
        assert!(e.contains("optimistic|reserve") && e.contains("eager"), "{e}");
        // conflicting mode flags are refused, not silently resolved —
        // through the new flag and the deprecated alias alike
        let e = parse_args(&s(&["--replicas", "2", "--admission", "reserve"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--admission reserve") && e.contains("single replica"), "{e}");
        let e = parse_args(&s(&["--replicas", "2", "--group-scheduler"])).unwrap_err().to_string();
        assert!(e.contains("single replica"), "{e}");
        let e = parse_args(&s(&["--spec-k", "2", "--draft-bits", "0"])).unwrap_err().to_string();
        assert!(e.contains("--draft-bits ≥ 1"), "{e}");
        let e = parse_args(&s(&["--spec-k", "2", "--admission", "reserve"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--admission optimistic"), "{e}");
    }
}
