//! apllm CLI — leader entrypoint.
//!
//! Subcommands:
//!   calibrate             print the gpusim calibration report (fit vs paper anchors)
//!   simulate M K N SCHEME simulate one GEMM (SCHEME: fp32|fp16|int4|int1|wXaY|apnn-wXaY)
//!   tables                print every paper table/figure reproduction
//!   gemm [--prec WxAy]    run a packed AP-GEMM through a PJRT artifact and verify vs bitmm
//!   serve [--requests N]  run the serving demo (PJRT artifacts, or the pack-once sim
//!                         backend with --sim; --replicas N serves a router-driven cluster)
//!
//! Argument parsing is hand-rolled (the build is offline; no clap).

use apllm::gpusim::{CalibrationReport, Gpu, Scheme, Simulator, ANCHORS};
use apllm::model::PrecisionConfig;

fn parse_scheme(s: &str) -> Option<Scheme> {
    match s.to_ascii_lowercase().as_str() {
        "fp32" => Some(Scheme::Fp32),
        "fp16" => Some(Scheme::Fp16),
        "int4" | "cutlass-int4" => Some(Scheme::CutlassInt4),
        "int1" | "cutlass-int1" => Some(Scheme::CutlassInt1),
        "bstc" => Some(Scheme::Bstc),
        "btc" => Some(Scheme::Btc),
        "qlora" => Some(Scheme::QloraW4),
        other => {
            if let Some(rest) = other.strip_prefix("apnn-") {
                PrecisionConfig::parse(rest).map(Scheme::ApnnTc)
            } else {
                PrecisionConfig::parse(other).map(Scheme::ours)
            }
        }
    }
}

fn cmd_calibrate() {
    let gpu = Gpu::rtx3090();
    println!("gpusim calibration vs paper anchors ({})", gpu.name);
    println!(
        "{:<16} {:>9} {:>12} {:>8}  worst  per-anchor (model / paper, µs)",
        "scheme", "launch µs", "rate ops/s", "s_half"
    );
    for (key, anchors) in ANCHORS.iter() {
        let rep = match CalibrationReport::build(&gpu, key, anchors) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("calibrate: {e}");
                std::process::exit(2);
            }
        };
        print!(
            "{:<16} {:>9.2} {:>12.3e} {:>8.0}  {:>4.0}%  ",
            rep.key,
            rep.params.launch_s * 1e6,
            rep.params.rate_ops,
            rep.params.s_half,
            rep.max_rel_err * 100.0
        );
        for ((m, k, n, t), model, _) in &rep.rows {
            print!("[{}x{}x{}: {:.1}/{:.1}] ", m, k, n, model * 1e6, t * 1e6);
        }
        println!();
    }
}

fn cmd_simulate(args: &[String]) {
    if args.len() < 4 {
        eprintln!("usage: apllm simulate M K N SCHEME");
        std::process::exit(2);
    }
    let dim = |i: usize, name: &str| -> usize {
        match args[i].parse() {
            Ok(v) if v > 0 => v,
            _ => {
                eprintln!("simulate: {name} must be a positive integer, got {:?}", args[i]);
                std::process::exit(2);
            }
        }
    };
    let (m, k, n) = (dim(0, "M"), dim(1, "K"), dim(2, "N"));
    let Some(scheme) = parse_scheme(&args[3]) else {
        eprintln!(
            "simulate: unknown scheme {:?} (valid: fp32, fp16, int4, int1, bstc, btc, qlora, \
             wXaY, apnn-wXaY)",
            args[3]
        );
        std::process::exit(2);
    };
    let sim = Simulator::rtx3090();
    // an uncalibrated-but-parseable scheme (e.g. apnn-w8a8) is a user
    // error, not a crash: report it and the valid options
    let r = match sim.simulate(&scheme, m, k, n) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("simulate: {e}");
            std::process::exit(2);
        }
    };
    println!("scheme       : {}", scheme.label());
    println!("shape        : {m} x {k} x {n}");
    println!("time         : {:.2} µs", r.time_s * 1e6);
    println!("  compute    : {:.2} µs", r.t_compute_s * 1e6);
    println!("  memory     : {:.2} µs", r.t_mem_s * 1e6);
    println!("  launch     : {:.2} µs", r.launch_s * 1e6);
    println!("  recovery   : {:.2} µs", r.t_recovery_s * 1e6);
    println!("util         : {:.1}%", r.util * 100.0);
    println!("traffic      : {:.2} MB", r.traffic_bytes / 1e6);
    println!("effective    : {:.1} TOPS", r.tops_effective(m, k, n));
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("calibrate") => cmd_calibrate(),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("tables") => apllm::bench::print_all_tables(),
        Some("gemm") => {
            #[cfg(feature = "pjrt")]
            apllm::runtime::cli::cmd_gemm(&args[1..]);
            #[cfg(not(feature = "pjrt"))]
            {
                eprintln!("gemm needs the PJRT runtime: rebuild with --features pjrt");
                std::process::exit(2);
            }
        }
        Some("serve") => apllm::coordinator::cli::cmd_serve(&args[1..]),
        _ => {
            eprintln!("usage: apllm <calibrate|simulate|tables|gemm|serve> [args]");
            std::process::exit(2);
        }
    }
}
