//! Regenerate EVERY table and figure of the paper's evaluation section in
//! one run (Table 1, Table 2, Fig. 5, Fig. 6, Fig. 7, plus the two
//! ablations) — the same output `cargo bench` produces, bundled for easy
//! comparison against the PDF.
//!
//! Run: `cargo run --release --example paper_tables`

fn main() {
    apllm::bench::print_all_tables();
    println!("(see EXPERIMENTS.md for the paper-vs-simulated comparison and calibration residuals)");
}
