//! Quickstart: the library in ~60 lines.
//!
//! 1. Quantize a float weight matrix to 2-bit bipolar-INT.
//! 2. Dynamically quantize activations.
//! 3. Run the arbitrary-precision MatMul (decompose → 1-bit XNOR-popcount
//!    GEMMs → fused shift-add recovery).
//! 4. Dequantize and compare against the float reference.
//!
//! Run: `cargo run --release --example quickstart`

use apllm::bitmm::{apmm_bipolar, transpose_codes, ApmmOpts};
use apllm::quant::{quantize_bipolar_per_channel, quantize_bipolar_per_tensor};
use apllm::util::Rng;

fn main() {
    let (out_features, in_features, tokens) = (512usize, 1024usize, 16usize);
    let (nw, nx) = (4u32, 4u32); // W4A4

    // a "trained" weight matrix and an activation batch
    let mut rng = Rng::with_seed(42);
    let w: Vec<f32> = (0..out_features * in_features).map(|_| rng.normal() * 0.05).collect();
    let x: Vec<f32> = (0..tokens * in_features).map(|_| rng.normal()).collect();

    // 1. offline: per-output-channel weight quantization
    let wq = quantize_bipolar_per_channel(&w, out_features, in_features, nw);

    // 2. online: per-token activation quantization
    let xq = quantize_bipolar_per_tensor(&x, tokens, in_features, nx);

    // 3. integer AP-GEMM: Y_int = Wq · Xqᵀ   (activations are N-major)
    let y_int = apmm_bipolar(&wq.codes, &xq.codes, ApmmOpts::default());

    // 4. dequantize: y = y_int · s_w[row] · s_x
    let sx = xq.scales[0];
    let mut max_rel = 0f32;
    let mut y = vec![0f32; out_features * tokens];
    for r in 0..out_features {
        for t in 0..tokens {
            y[r * tokens + t] = y_int[r * tokens + t] as f32 * wq.scales[r] * sx;
        }
    }

    // float reference for error reporting (relative L2 over the output)
    let mut se = 0f64;
    let mut sref = 0f64;
    for r in 0..out_features {
        for t in 0..tokens {
            let mut acc = 0f32;
            for c in 0..in_features {
                acc += w[r * in_features + c] * x[t * in_features + c];
            }
            let d = y[r * tokens + t] - acc;
            se += (d * d) as f64;
            sref += (acc * acc) as f64;
            max_rel = max_rel.max(d.abs() / acc.abs().max(1.0));
        }
    }
    let rel_l2 = (se / sref).sqrt();

    println!("W{nw}A{nx} AP-GEMM: {out_features}x{in_features} weights × {tokens} tokens");
    println!("packed weight footprint: {} bytes (f32 would be {})",
        out_features * in_features * nw as usize / 8,
        out_features * in_features * 4);
    println!("output error vs f32 reference: rel-L2 {rel_l2:.3}, worst element {max_rel:.3}");
    assert!(rel_l2 < 0.25, "quantization error out of expected band: {rel_l2}");

    // bonus: transpose helper demo (normal (K,N) activations)
    let xt = transpose_codes(&xq.codes);
    assert_eq!(xt.rows, in_features);
    println!("OK");
}
