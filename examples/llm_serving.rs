//! END-TO-END driver (DESIGN.md E2E): serve a batched request stream
//! through the continuous-batching coordinator and report
//! latency/throughput.
//!
//! With the `pjrt` feature, this loads the AOT-compiled quantized model
//! artifacts and runs the real PJRT runtime — proving all three layers
//! compose (L1 Pallas AP-GEMM kernels inside the L2 JAX model, AOT-lowered
//! to HLO, executed by the L3 Rust coordinator) with Python never running.
//! Without it (the default offline build), the **continuous-batching
//! engine** serves real bitmm logits through the §3.3 pack-once pipeline:
//! weights packed once at startup, each step packing only its activation
//! batch through the recycling arena, sequences joining and leaving the
//! batch every iteration (swap-preemption under KV pressure), prompt
//! prefixes sharing refcounted KV blocks, every token streamed as a
//! `TokenEvent`.  With `--replicas N` (≥2) the workload is served by a
//! **router-driven cluster** of N engine replicas
//! (`--route-policy round-robin|least-loaded`).
//!
//! Run: `cargo run --release --example llm_serving -- [--requests N] [--rate R] [--sim]
//!       [--replicas N] [--route-policy least-loaded]`
//! (PJRT path additionally needs `make artifacts` and `--features pjrt`;
//! `--admission reserve` books each request's full budget up front and
//! never preempts — the retired group scheduler's semantics.)

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut a = match apllm::coordinator::cli::parse_args(&args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("llm_serving: {e}");
            std::process::exit(2);
        }
    };
    if args.is_empty() {
        // demo defaults: enough load that batching engages
        a.requests = 24;
        a.rate_per_s = 40.0;
        a.max_new = 8;
        a.prompt_len = 12;
    }
    match apllm::coordinator::cli::run_demo(&a) {
        Ok(report) => {
            println!("{report}");
            println!("(record this run in EXPERIMENTS.md §E2E)");
        }
        Err(e) => {
            eprintln!("llm_serving failed: {e:#}\nhint: run `make artifacts` first");
            std::process::exit(1);
        }
    }
}
