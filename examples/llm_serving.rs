//! END-TO-END driver (DESIGN.md E2E): load the AOT-compiled quantized
//! model artifacts, serve a batched request stream through the
//! continuous-batching coordinator over the real PJRT runtime, and report
//! latency/throughput.
//!
//! This proves all three layers compose: L1 Pallas AP-GEMM kernels inside
//! the L2 JAX model, AOT-lowered to HLO, executed by the L3 Rust
//! coordinator with dynamic batching + per-slot KV positions — Python
//! never runs.
//!
//! Run: `make artifacts && cargo run --release --example llm_serving -- [--requests N] [--rate R]`

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut a = apllm::coordinator::cli::parse_args(&args);
    if args.is_empty() {
        // demo defaults: enough load that batching engages
        a.requests = 24;
        a.rate_per_s = 40.0;
        a.max_new = 8;
        a.prompt_len = 12;
    }
    match apllm::coordinator::cli::run_serving_demo(&a) {
        Ok(report) => {
            println!("{report}");
            println!("(record this run in EXPERIMENTS.md §E2E)");
        }
        Err(e) => {
            eprintln!("llm_serving failed: {e:#}\nhint: run `make artifacts` first");
            std::process::exit(1);
        }
    }
}
