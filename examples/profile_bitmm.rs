//! Profiling helper for the §Perf pass: splits the bitmm hot path into
//! packing vs prepacked GEMM-core time (EXPERIMENTS.md §Perf iteration
//! log) — the measured version of the §3.3 pack-once argument.
//!
//! Run: `cargo run --release --example profile_bitmm`

use apllm::bitmm::{apmm_bipolar, apmm_bipolar_packed, pack_codes, ApmmOpts, CodeMatrix};
use std::time::Instant;

fn main() {
    let (m, k, n) = (256usize, 2048usize, 256usize);
    let w = CodeMatrix::random(m, k, 2, 1);
    let xt = CodeMatrix::random(n, k, 2, 2);
    let wp = pack_codes(&w);
    let xp = pack_codes(&xt);
    for _ in 0..2 {
        let _ = pack_codes(&w);
    }

    let t0 = Instant::now();
    for _ in 0..10 {
        std::hint::black_box(pack_codes(&w));
        std::hint::black_box(pack_codes(&xt));
    }
    let t_pack = t0.elapsed() / 10;
    println!("pack both operands : {t_pack:?}/iter");

    let t0 = Instant::now();
    for _ in 0..10 {
        std::hint::black_box(apmm_bipolar_packed(&wp, &xp, ApmmOpts::default()));
    }
    let t_core = t0.elapsed() / 10;
    println!("prepacked core     : {t_core:?}/iter");

    let t0 = Instant::now();
    for _ in 0..10 {
        std::hint::black_box(apmm_bipolar(&w, &xt, ApmmOpts::default()));
    }
    let t_total = t0.elapsed() / 10;
    println!("pack+compute total : {t_total:?}/iter");
    println!(
        "pack share if inline: {:.1}% (the pack-once ABI pays it exactly once)",
        100.0 * t_pack.as_secs_f64() / t_total.as_secs_f64()
    );
}
