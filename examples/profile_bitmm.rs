//! Profiling helper for the §Perf pass: splits the bitmm hot path into
//! packing vs GEMM-core time (EXPERIMENTS.md §Perf iteration log).
//!
//! Run: `cargo run --release --example profile_bitmm`

use apllm::bitmm::{pack_codes, apmm_bipolar, ApmmOpts, CodeMatrix};
use std::time::Instant;
fn main() {
    let (m, k, n) = (256usize, 2048usize, 256usize);
    let w = CodeMatrix::random(m, k, 2, 1);
    let xt = CodeMatrix::random(n, k, 2, 2);
    for _ in 0..2 { let _ = pack_codes(&w); }
    let t0 = Instant::now();
    for _ in 0..10 { std::hint::black_box(pack_codes(&w)); std::hint::black_box(pack_codes(&xt)); }
    println!("pack both: {:?}/iter", t0.elapsed()/10);
    let t0 = Instant::now();
    for _ in 0..10 { std::hint::black_box(apmm_bipolar(&w, &xt, ApmmOpts::default())); }
    println!("apmm total: {:?}/iter", t0.elapsed()/10);
}
