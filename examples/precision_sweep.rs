//! Precision sweep: the accuracy ↔ speed trade-off across W{n}A{m}.
//!
//! For every precision the paper's Fig. 7 exercises (plus the full grid),
//! this measures (a) quantization error of a realistic weight/activation
//! pair on the CPU substrate and (b) the simulated RTX 3090 speedup over
//! FP16 on Llama2-7B — the two axes a deployment actually trades.
//!
//! Run: `cargo run --release --example precision_sweep`

use apllm::bitfmt::IntFormat;
use apllm::bitmm::{apmm_bipolar, ApmmOpts, CodeMatrix};
use apllm::gpusim::{Scheme, Simulator};
use apllm::model::{LlmArch, PrecisionConfig};
use apllm::quant::{dequantize, quant_error, quantize_bipolar_per_channel, quantize_bipolar_per_tensor};
use apllm::util::Rng;

fn main() {
    let sim = Simulator::rtx3090();
    let arch = LlmArch::llama2_7b();
    let (out_f, in_f, toks) = (256usize, 1024usize, 32usize);

    let mut rng = Rng::with_seed(7);
    let w: Vec<f32> = (0..out_f * in_f).map(|_| rng.normal() * 0.04).collect();
    let x: Vec<f32> = (0..toks * in_f).map(|_| rng.normal()).collect();

    // float reference output
    let mut y_ref = vec![0f32; out_f * toks];
    for r in 0..out_f {
        for t in 0..toks {
            let mut acc = 0f32;
            for c in 0..in_f {
                acc += w[r * in_f + c] * x[t * in_f + c];
            }
            y_ref[r * toks + t] = acc;
        }
    }

    println!(
        "{:<8} {:>14} {:>14} {:>16} {:>18}",
        "config", "weight relL2", "output relL2", "weight bytes", "sim speedup/FP16"
    );
    for (nw, nx) in [(1, 1), (1, 2), (2, 2), (3, 2), (3, 4), (4, 4), (6, 6), (8, 8)] {
        let p = PrecisionConfig::new(nw, nx);
        let wq = quantize_bipolar_per_channel(&w, out_f, in_f, nw);
        let xq = quantize_bipolar_per_tensor(&x, toks, in_f, nx);

        // weight reconstruction error
        let werr = quant_error(&w, &dequantize(&wq, IntFormat::Bipolar));

        // end-to-end output error through the real integer kernel
        let y_int = apmm_bipolar(&wq.codes, &xq.codes, ApmmOpts::default());
        let sx = xq.scales[0];
        let y: Vec<f32> = (0..out_f * toks)
            .map(|i| y_int[i] as f32 * wq.scales[i / toks] * sx)
            .collect();
        let oerr = quant_error(&y_ref, &y);

        // simulated LLM speedup — precisions outside the calibrated set
        // come back as a clean error, rendered as "-"
        let speedup = match sim.llm_speedup_vs_fp16(&arch, &Scheme::ours(p), 1024) {
            Ok(sp) => format!("{sp:.2}×"),
            Err(_) => "-".into(),
        };
        println!(
            "{:<8} {:>14.4} {:>14.4} {:>16} {:>18}",
            p.label(),
            werr.rel_l2,
            oerr.rel_l2,
            out_f * in_f * nw as usize / 8,
            speedup
        );
    }
    println!("\n(error decreases monotonically with bits; speedup decreases with n_w·n_x —");
    println!(" the deployment picks the knee; the paper's Fig. 7 configs are W1A1/W2A2/W4A4)");

    // sanity: the sweep's monotonicity claims hold
    let err_at = |bits: u32| {
        let wq = quantize_bipolar_per_channel(&w, out_f, in_f, bits);
        quant_error(&w, &dequantize(&wq, IntFormat::Bipolar)).rel_l2
    };
    assert!(err_at(1) > err_at(2) && err_at(2) > err_at(4) && err_at(4) > err_at(8));

    // demo CodeMatrix invariants for documentation purposes
    let cm = CodeMatrix::random(4, 8, 3, 1);
    assert!(cm.decode(IntFormat::Bipolar).iter().all(|v| v.abs() <= 7));
}
